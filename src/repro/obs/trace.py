"""Wall-time trace spans with thread-local nesting.

Two context managers:

:func:`span`
    Host-side wall-time span for code that runs eagerly (plan build,
    plan execute, exporter flush).  Records an event into the process
    buffer when ``REPRO_OBS=trace``; otherwise it is a shared no-op
    object, so the disabled path is one function call and an int
    compare.

:func:`stage`
    For code that runs *under a jax trace* (engine schedule stages,
    kernel dispatch).  Always enters ``jax.named_scope`` — that is
    trace-time-only metadata, free at runtime, and makes the stage
    visible in XLA HLO names and ``jax.profiler`` output even with obs
    off.  When tracing is enabled it additionally records a span event;
    since the wrapped code executes at *trace* time for jitted paths,
    the recorded duration is the tracing/staging cost of that stage,
    not device runtime (device-side timing comes from ``jax.profiler``
    via the same named scopes).

Events use the Chrome-trace "complete" (``ph: "X"``) model: name,
category, start timestamp and duration in microseconds, plus the
nesting depth at record time.  The buffer is bounded; overflow bumps a
dropped-events counter rather than growing without limit.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

import jax

from repro.obs import config as _cfg

_EPOCH = time.perf_counter()      # process-relative origin for timestamps
_MAX_EVENTS = 100_000

_lock = threading.Lock()
_events: List[Dict[str, Any]] = []
_dropped = 0
_tls = threading.local()


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def _now_us() -> float:
    return (time.perf_counter() - _EPOCH) * 1e6


def _record(name: str, cat: str, ts_us: float, dur_us: float, depth: int,
            args: Optional[Dict[str, Any]]) -> None:
    global _dropped
    ev = {"name": name, "cat": cat, "ts": ts_us, "dur": dur_us,
          "depth": depth, "tid": threading.get_ident()}
    if args:
        ev["args"] = args
    with _lock:
        if len(_events) >= _MAX_EVENTS:
            _dropped += 1
        else:
            _events.append(ev)


@contextmanager
def _noop() -> Iterator[None]:
    yield


@contextmanager
def span(name: str, *, cat: str = "host", sync: Any = None,
         **attrs: Any) -> Iterator[None]:
    """Wall-time span around eager host code.

    ``sync`` — an optional value (array / pytree) passed to
    ``jax.block_until_ready`` before the clock stops, so the span covers
    device work dispatched inside it rather than dispatch alone.
    """
    if not _cfg.trace_enabled():
        if sync is not None:
            jax.block_until_ready(sync)
        yield
        return
    st = _stack()
    depth = len(st)
    st.append(name)
    t0 = _now_us()
    try:
        yield
    finally:
        if sync is not None:
            jax.block_until_ready(sync)
        t1 = _now_us()
        st.pop()
        _record(name, cat, t0, t1 - t0, depth, attrs or None)


def stage(name: str, **attrs: Any):
    """Scope for code executing under a jax trace (see module docstring)."""
    scope = jax.named_scope(name)
    if not _cfg.trace_enabled():
        return scope

    @contextmanager
    def _staged() -> Iterator[None]:
        st = _stack()
        depth = len(st)
        st.append(name)
        t0 = _now_us()
        try:
            with scope:
                yield
        finally:
            t1 = _now_us()
            st.pop()
            _record(name, "stage", t0, t1 - t0, depth, attrs or None)

    return _staged()


def events() -> List[Dict[str, Any]]:
    """Snapshot of recorded span events (oldest first)."""
    with _lock:
        return list(_events)


def dropped_events() -> int:
    with _lock:
        return _dropped


def reset() -> None:
    """Clear the event buffer (test hook)."""
    global _dropped
    with _lock:
        _events.clear()
        _dropped = 0
