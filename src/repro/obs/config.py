"""Observability mode state.

One process-wide switch, three levels::

    off      nothing recorded; no host callbacks staged   (default)
    metrics  counters / gauges / histograms only
    trace    metrics + wall-time spans + convergence telemetry
             (jax.debug.callback streams baked into traced code)

Configured by the ``REPRO_OBS`` environment variable at import, or at
runtime via :func:`configure`.  The env var is parsed strictly — a typo
fails fast with the valid choices, same contract as
``REPRO_KERNEL_BACKEND`` in kernels/ops.py.

Levels are ordered: ``trace`` implies ``metrics``.  Call sites gate with
:func:`metrics_enabled` / :func:`trace_enabled`; both are attribute
reads plus an int compare, so the disabled path costs nanoseconds.
"""
from __future__ import annotations

import os

ENV_VAR = "REPRO_OBS"
ENV_DIR = "REPRO_OBS_DIR"

MODES = ("off", "metrics", "trace")
_LEVEL = {"off": 0, "metrics": 1, "trace": 2}


def _parse(raw: str, *, source: str) -> str:
    mode = raw.strip().lower()
    if mode not in MODES:
        raise ValueError(f"{source}={raw!r}: choose one of {MODES}")
    return mode


class _State:
    __slots__ = ("mode", "level", "out_dir")

    def __init__(self) -> None:
        self.mode = _parse(os.environ.get(ENV_VAR) or "off", source=ENV_VAR)
        self.level = _LEVEL[self.mode]
        self.out_dir = os.environ.get(ENV_DIR) or "obs_out"


_STATE = _State()


def mode() -> str:
    """Current observability mode: ``off`` | ``metrics`` | ``trace``."""
    return _STATE.mode


def out_dir() -> str:
    """Directory the atexit exporters write to (``REPRO_OBS_DIR``)."""
    return _STATE.out_dir


def metrics_enabled() -> bool:
    return _STATE.level >= 1


def trace_enabled() -> bool:
    return _STATE.level >= 2


def configure(mode: str | None = None, *, out_dir: str | None = None) -> str:
    """Set the observability mode (and/or export dir) at runtime.

    Returns the active mode.  Note that flipping the mode does NOT
    invalidate jit caches: telemetry callbacks are staged at *trace*
    time, so functions already compiled under the previous mode keep
    their old instrumentation until retraced.
    """
    if mode is not None:
        _STATE.mode = _parse(mode, source="configure(mode=...)")
        _STATE.level = _LEVEL[_STATE.mode]
    if out_dir is not None:
        _STATE.out_dir = out_dir
    return _STATE.mode
