"""Exporters: Chrome-trace JSON, JSONL event log, Prometheus text.

Chrome-trace output is the standard ``traceEvents`` array of complete
(``ph: "X"``) events — load it at https://ui.perfetto.dev or
``chrome://tracing``.  Perfetto reconstructs nesting from time
containment per ``(pid, tid)``, which matches how the span stack
records.

When ``REPRO_OBS`` is set (not ``off``) in the environment, an atexit
hook writes all three artifacts to ``REPRO_OBS_DIR`` (default
``obs_out/``): ``trace.json``, ``events.jsonl``, ``metrics.prom``.
That is how ``REPRO_OBS=trace python examples/quickstart.py`` produces
a loadable trace with no code changes.

``python -m repro.obs validate <trace.json>`` checks an artifact from
the command line (used by the CI trace-smoke step).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, List, Optional

from repro.obs import config as _cfg
from repro.obs import metrics as _metrics
from repro.obs import telemetry as _telemetry
from repro.obs import trace as _trace


def chrome_trace(events: Optional[List[Dict[str, Any]]] = None) -> dict:
    """Render span events as a Chrome-trace dict."""
    evs = _trace.events() if events is None else events
    pid = os.getpid()
    out = []
    for ev in evs:
        te = {"name": ev["name"], "cat": ev.get("cat", "host"), "ph": "X",
              "ts": round(ev["ts"], 3), "dur": round(max(ev["dur"], 0.0), 3),
              "pid": pid, "tid": ev.get("tid", 0)}
        args = dict(ev.get("args") or {})
        args["depth"] = ev.get("depth", 0)
        te["args"] = args
        out.append(te)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def export_chrome_trace(path: str) -> str:
    doc = chrome_trace()
    _ensure_parent(path)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


def export_jsonl(path: str) -> str:
    """One JSON object per line: spans, then metrics, then telemetry."""
    _ensure_parent(path)
    with open(path, "w") as f:
        for ev in _trace.events():
            f.write(json.dumps({"kind": "span", **ev}) + "\n")
        snap = _metrics.snapshot()
        for group in ("counters", "gauges"):
            for name, val in snap[group].items():
                f.write(json.dumps(
                    {"kind": group[:-1], "name": name, "value": val}) + "\n")
        for name, h in snap["histograms"].items():
            f.write(json.dumps(
                {"kind": "histogram", "name": name, **h}) + "\n")
        for name, n in _telemetry.peek().items():
            f.write(json.dumps(
                {"kind": "stream", "name": name, "buffered": n}) + "\n")
    return path


def export_metrics(path: str) -> str:
    _ensure_parent(path)
    with open(path, "w") as f:
        f.write(_metrics.prometheus_text())
    return path


def _ensure_parent(path: str) -> None:
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)


# ----------------------------------------------------------- validation
def validate_chrome_trace(path: str) -> Dict[str, Any]:
    """Validate a Chrome-trace JSON file; raise ValueError on problems.

    Returns a summary: event count, distinct span names, max depth.
    """
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError(f"{path}: missing top-level 'traceEvents'")
    evs = doc["traceEvents"]
    if not isinstance(evs, list) or not evs:
        raise ValueError(f"{path}: 'traceEvents' must be a non-empty list")
    names = set()
    max_depth = 0
    for i, ev in enumerate(evs):
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"{path}: event {i} missing {field!r}")
        if ev["ph"] == "X":
            if "dur" not in ev or ev["dur"] < 0:
                raise ValueError(
                    f"{path}: event {i} ({ev['name']}) has bad 'dur'")
        names.add(ev["name"])
        max_depth = max(max_depth, int(ev.get("args", {}).get("depth", 0)))
    return {"events": len(evs), "names": sorted(names),
            "max_depth": max_depth}


# ------------------------------------------------------- atexit install
def write_all(out_dir: Optional[str] = None) -> Dict[str, str]:
    """Write trace.json / events.jsonl / metrics.prom into ``out_dir``."""
    d = out_dir or _cfg.out_dir()
    _telemetry.flush()
    return {
        "trace": export_chrome_trace(os.path.join(d, "trace.json")),
        "events": export_jsonl(os.path.join(d, "events.jsonl")),
        "metrics": export_metrics(os.path.join(d, "metrics.prom")),
    }


_atexit_installed = False


def install_atexit() -> None:
    """Register a best-effort artifact dump at interpreter exit."""
    global _atexit_installed
    if _atexit_installed:
        return
    _atexit_installed = True
    import atexit

    def _dump() -> None:
        if _cfg.mode() == "off":
            return
        try:
            paths = write_all()
        except Exception as exc:          # never fail the host program
            print(f"[repro.obs] artifact export failed: {exc}")
            return
        print(f"[repro.obs] wrote {paths['trace']}")

    atexit.register(_dump)


# -------------------------------------------------------- /metrics HTTP
def start_metrics_server(port: int = 0, host: str = "127.0.0.1"):
    """Serve the metrics registry over HTTP on a daemon thread.

    ``GET /metrics`` returns Prometheus text; ``GET /`` a tiny index.
    Returns the ``ThreadingHTTPServer`` — read the bound port from
    ``server.server_address[1]`` (useful with ``port=0``), stop with
    ``server.shutdown()``.
    """
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802  (stdlib casing)
            if self.path.rstrip("/") in ("", "/index.html"):
                body = b"repro.obs metrics endpoint; see /metrics\n"
                ctype = "text/plain; charset=utf-8"
            elif self.path == "/metrics":
                body = _metrics.prometheus_text().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            else:
                self.send_response(404)
                self.end_headers()
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):     # keep stdout clean
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    thread = threading.Thread(target=server.serve_forever, daemon=True,
                              name="repro-obs-metrics")
    thread.start()
    return server


def add_metrics_cli(parser) -> None:
    """Install the standard ``--metrics-port`` / ``--metrics-hold`` flags.

    Shared by every serving-style entrypoint (``repro.launch.serve``,
    ``repro.serve``) so the scrape surface is spelled the same way
    everywhere.  Pair with `start_metrics_from_args`.
    """
    parser.add_argument(
        "--metrics-port", type=int, default=None, metavar="PORT",
        help="serve repro.obs metrics on http://127.0.0.1:PORT/metrics "
             "(0 picks a free port)")
    parser.add_argument(
        "--metrics-hold", type=float, default=0.0, metavar="S",
        help="keep the process alive S seconds after the run so the "
             "/metrics endpoint can be scraped")


def start_metrics_from_args(args):
    """Start (and announce) the metrics server if ``--metrics-port`` was
    given; returns the server or ``None``."""
    if getattr(args, "metrics_port", None) is None:
        return None
    server = start_metrics_server(args.metrics_port)
    host, port = server.server_address[:2]
    print(f"metrics: http://{host}:{port}/metrics")
    return server
