"""Process-wide metrics registry: counters, gauges, histograms.

Zero-dependency and deliberately small.  Metrics are keyed by
``(name, sorted(labels))``; names are dotted (``plan.cache.hits``) and
mangled to Prometheus form only at export time.  All mutation goes
through one lock — call sites are host-side (plan build/execute, cache
lookups, shim invocations), never inside a traced computation, so the
lock is uncontended in practice but makes the ``/metrics`` endpoint
thread safe.

Everything is a no-op unless ``REPRO_OBS`` is ``metrics`` or ``trace``.
"""
from __future__ import annotations

import math
import threading
from typing import Any, Dict, List, Optional, Tuple

from repro.obs import config as _cfg

_Key = Tuple[str, Tuple[Tuple[str, str], ...]]

_lock = threading.Lock()
_counters: Dict[_Key, float] = {}
_gauges: Dict[_Key, float] = {}
_hists: Dict[_Key, Dict[str, float]] = {}

# bounded per-histogram sample reservoirs backing `quantile`; kept out of
# the histogram summary dicts so snapshot()/prometheus output is unchanged
_RESERVOIR = 2048
_samples: Dict[_Key, List[float]] = {}


def _key(name: str, labels: Dict[str, Any]) -> _Key:
    return name, tuple(sorted((k, str(v)) for k, v in labels.items()))


def inc(name: str, value: float = 1.0, **labels: Any) -> None:
    """Increment a counter (creates it at 0 on first touch)."""
    if not _cfg.metrics_enabled():
        return
    k = _key(name, labels)
    with _lock:
        _counters[k] = _counters.get(k, 0.0) + value


def set_gauge(name: str, value: float, **labels: Any) -> None:
    if not _cfg.metrics_enabled():
        return
    k = _key(name, labels)
    with _lock:
        _gauges[k] = float(value)


def observe(name: str, value: float, **labels: Any) -> None:
    """Record one observation into a histogram (count/sum/min/max)."""
    if not _cfg.metrics_enabled():
        return
    v = float(value)
    if math.isnan(v):
        return
    k = _key(name, labels)
    with _lock:
        h = _hists.get(k)
        if h is None:
            h = _hists[k] = {"count": 0.0, "sum": 0.0,
                             "min": math.inf, "max": -math.inf}
        h["count"] += 1
        h["sum"] += v
        h["min"] = min(h["min"], v)
        h["max"] = max(h["max"], v)
        s = _samples.setdefault(k, [])
        s.append(v)
        if len(s) > _RESERVOIR:
            # deterministic decimation: keep every other sample.  Coarser
            # than true reservoir sampling but reproducible, and fine for
            # the p50/p99 operational readouts this backs.
            _samples[k] = s[::2]


def quantile(name: str, q: float, **labels: Any) -> Optional[float]:
    """Linear-interpolated quantile over a histogram's sample reservoir.

    ``q`` in [0, 1].  Returns ``None`` when nothing has been observed
    (including when metrics are disabled).  Backed by a bounded reservoir
    (the last ~``_RESERVOIR`` observations, decimated), so treat it as an
    operational readout, not an exact statistic.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    with _lock:
        s = _samples.get(_key(name, labels))
        if not s:
            return None
        s = sorted(s)
    pos = q * (len(s) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (pos - lo)


def counter_value(name: str, **labels: Any) -> float:
    """Read a counter (0.0 if never incremented) — test/report hook."""
    with _lock:
        return _counters.get(_key(name, labels), 0.0)


def snapshot() -> Dict[str, Dict[str, Any]]:
    """A plain-dict copy of the whole registry.

    Keys are rendered as ``name`` or ``name{k=v,...}``; histograms map
    to their summary dicts.
    """

    def render(k: _Key) -> str:
        name, labels = k
        if not labels:
            return name
        inner = ",".join(f"{lk}={lv}" for lk, lv in labels)
        return f"{name}{{{inner}}}"

    with _lock:
        return {
            "counters": {render(k): v for k, v in _counters.items()},
            "gauges": {render(k): v for k, v in _gauges.items()},
            "histograms": {render(k): dict(v) for k, v in _hists.items()},
        }


def _prom_name(name: str) -> str:
    out = []
    for ch in name:
        out.append(ch if ch.isalnum() or ch == "_" else "_")
    return "repro_" + "".join(out)


def _prom_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


def prometheus_text() -> str:
    """Render the registry in Prometheus text exposition format."""
    lines: List[str] = []
    with _lock:
        counters = dict(_counters)
        gauges = dict(_gauges)
        hists = {k: dict(v) for k, v in _hists.items()}
    seen_type: Dict[str, str] = {}

    def header(pname: str, kind: str) -> None:
        if seen_type.get(pname) != kind:
            seen_type[pname] = kind
            lines.append(f"# TYPE {pname} {kind}")

    for (name, labels), v in sorted(counters.items()):
        pname = _prom_name(name) + "_total"
        header(pname, "counter")
        lines.append(f"{pname}{_prom_labels(labels)} {v:g}")
    for (name, labels), v in sorted(gauges.items()):
        pname = _prom_name(name)
        header(pname, "gauge")
        lines.append(f"{pname}{_prom_labels(labels)} {v:g}")
    for (name, labels), h in sorted(hists.items()):
        pname = _prom_name(name)
        header(pname, "summary")
        lab = _prom_labels(labels)
        lines.append(f"{pname}_count{lab} {h['count']:g}")
        lines.append(f"{pname}_sum{lab} {h['sum']:g}")
        if h["count"]:
            lines.append(f"{pname}_min{lab} {h['min']:g}")
            lines.append(f"{pname}_max{lab} {h['max']:g}")
    return "\n".join(lines) + ("\n" if lines else "")


def reset() -> None:
    """Clear the registry (test hook)."""
    with _lock:
        _counters.clear()
        _gauges.clear()
        _hists.clear()
        _samples.clear()
