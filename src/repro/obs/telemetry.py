"""Convergence telemetry: host-side streams fed by ``jax.debug.callback``.

The estimators run under jit; their per-probe / per-iteration state
lives on device.  When ``REPRO_OBS=trace`` *at trace time*, the
estimator modules stage a ``jax.debug.callback`` that ships small
arrays (a running-`sem` curve, one CG residual per iteration) to a
process-wide buffer here.  The gate is checked while tracing, so with
obs off **nothing is staged** — the lowered HLO contains no host
callbacks at all (asserted in tests/test_obs.py), which is how the
<1%-overhead-when-disabled budget is met.

Two emit shapes:

:func:`emit_curve`
    One callback per execution carrying a whole 1-D curve (e.g. the
    running sem over probes 1..k, computed vectorized on device via
    :func:`running_sem`).

:func:`emit_point`
    One callback per loop iteration carrying ``(step, value)`` — used
    inside ``lax.while_loop`` bodies (CG residual).  Callbacks may
    arrive out of order; :func:`drain` sorts by step.

Callbacks are asynchronous: call :func:`flush` (→ ``jax.effects_barrier``)
before draining.
"""
from __future__ import annotations

import functools
import math
import threading
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import config as _cfg

_lock = threading.Lock()
_curves: Dict[str, List[float]] = {}
_points: Dict[str, List[tuple]] = {}


def enabled() -> bool:
    """True when telemetry callbacks should be staged (trace mode)."""
    return _cfg.trace_enabled()


# ---------------------------------------------------------------- sinks
def _sink_curve(name: str, values: Any) -> None:
    vals = [float(v) for v in np.asarray(values).ravel()]
    with _lock:
        _curves.setdefault(name, []).extend(vals)


def _sink_point(name: str, step: Any, value: Any) -> None:
    with _lock:
        _points.setdefault(name, []).append(
            (int(np.asarray(step)), float(np.asarray(value))))


# ---------------------------------------------------------------- emits
def emit_curve(name: str, values: jax.Array) -> None:
    """Stage a callback shipping a 1-D curve off device (trace mode only)."""
    if not enabled():
        return
    jax.debug.callback(functools.partial(_sink_curve, name), values)


def emit_point(name: str, value: jax.Array, step: jax.Array) -> None:
    """Stage a per-iteration callback (trace mode only)."""
    if not enabled():
        return
    jax.debug.callback(functools.partial(_sink_point, name), step, value)


# ------------------------------------------------------------- helpers
def running_sem(samples: jax.Array) -> jax.Array:
    """Running standard error over sample prefixes, vectorized.

    ``samples[..., j]`` is the j-th probe's estimate; returns a curve of
    shape (k,) where entry j-1 is the sem of the first j probes
    (batch-averaged if ``samples`` has leading dims).  Entry 0 is inf —
    a single probe has no spread estimate.
    """
    x = jnp.asarray(samples)
    x = x.reshape(-1, x.shape[-1]) if x.ndim > 1 else x[None]
    k = x.shape[-1]
    idx = jnp.arange(1, k + 1, dtype=x.dtype)
    mean = jnp.cumsum(x, axis=-1) / idx
    var = (jnp.cumsum(x * x, axis=-1) - idx * mean * mean) / jnp.maximum(
        idx - 1.0, 1.0)
    sem = jnp.sqrt(jnp.maximum(var, 0.0) / idx)
    sem = sem.at[..., 0].set(jnp.inf)
    return sem.mean(axis=0)


def flush() -> None:
    """Block until all staged debug callbacks have run."""
    if hasattr(jax, "effects_barrier"):
        jax.effects_barrier()


def drain() -> Dict[str, List[float]]:
    """Pop and return all buffered streams as ``{name: [floats]}``.

    Point streams are sorted by step.  Non-finite values are kept (the
    exporters sanitize them); call :func:`flush` first.
    """
    with _lock:
        curves = {k: list(v) for k, v in _curves.items()}
        points = {k: sorted(v) for k, v in _points.items()}
        _curves.clear()
        _points.clear()
    out: Dict[str, List[float]] = dict(curves)
    for name, pts in points.items():
        out[name] = [v for _, v in pts]
    return out


def peek() -> Dict[str, int]:
    """Stream names -> buffered lengths, without draining."""
    with _lock:
        out = {k: len(v) for k, v in _curves.items()}
        out.update({k: len(v) for k, v in _points.items()})
    return out


def sanitize(values: List[float]) -> List[Any]:
    """Replace non-finite entries with None for strict-JSON export."""
    return [v if math.isfinite(v) else None for v in values]


def reset() -> None:
    with _lock:
        _curves.clear()
        _points.clear()
