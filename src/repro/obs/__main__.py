"""CLI: ``python -m repro.obs validate <trace.json>``.

Used by the CI trace-smoke step to check the Chrome-trace artifact a
``REPRO_OBS=trace`` run produced.  ``--require name`` (repeatable)
additionally asserts a span name is present; ``--require-prefix`` any
span with the prefix.
"""
from __future__ import annotations

import argparse
import sys

from repro.obs.export import validate_chrome_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)
    v = sub.add_parser("validate", help="validate a Chrome-trace JSON file")
    v.add_argument("path")
    v.add_argument("--require", action="append", default=[],
                   metavar="NAME", help="span name that must be present")
    v.add_argument("--require-prefix", action="append", default=[],
                   metavar="PREFIX",
                   help="at least one span name must start with PREFIX")
    args = ap.parse_args(argv)

    try:
        summary = validate_chrome_trace(args.path)
    except (OSError, ValueError, KeyError) as exc:
        print(f"INVALID: {exc}", file=sys.stderr)
        return 1
    names = set(summary["names"])
    missing = [n for n in args.require if n not in names]
    for pfx in args.require_prefix:
        if not any(n.startswith(pfx) for n in names):
            missing.append(f"{pfx}*")
    if missing:
        print(f"INVALID: {args.path} has no span(s): {missing}; "
              f"present: {sorted(names)}", file=sys.stderr)
        return 1
    print(f"OK: {args.path} — {summary['events']} events, "
          f"max depth {summary['max_depth']}, "
          f"{len(names)} distinct spans")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
