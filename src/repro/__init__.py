"""repro — parallel matrix condensation for log-determinants, at scale.

Public entry point is the plan/execute API::

    import repro

    p = repro.plan((4096, 4096), method="auto")   # compile once
    result = p(a)                                 # LogdetResult
    result2 = p(a2)                               # no re-trace

`repro.plan` resolves the method (``"auto"`` runs a cost model over size,
operator structure, device count and requested accuracy), validates a
typed config, and returns a `LogdetPlan` holding a pre-jitted executable.
Every path returns a `LogdetResult` (sign, logabsdet, sem, method_used,
diagnostics).  Subsystems:

  repro.core         exact condensation / elimination kernels + the plan
  repro.estimators   stochastic estimators, LinearOperator backends, VJPs
  repro.kernels      Pallas kernels (matvec, stencil, condensation steps)

The legacy string API (``repro.core.slogdet``) survives one release as a
deprecated shim — see docs/api.md for the migration guide.
"""
from repro.core.calibration import Calibration, load_calibration
from repro.core.configs import (
    ChebyshevConfig, EngineConfig, ExactConfig, SLQConfig,
)
from repro.core.result import Diagnostics, LogdetResult
from repro.core.plan import (
    LogdetPlan, ProblemSpec, plan, select_method, select_route, spec_of,
)

__all__ = [
    "plan", "LogdetPlan", "ProblemSpec", "select_method", "select_route",
    "spec_of", "load_plan",
    "ExactConfig", "EngineConfig", "ChebyshevConfig", "SLQConfig",
    "Calibration", "load_calibration",
    "LogdetResult", "Diagnostics",
]


def load_plan(path: str, **kwargs) -> LogdetPlan:
    """Load an AOT-exported plan artifact (see `LogdetPlan.export`).

    The returned plan executes the deserialized XLA binary directly —
    zero traces, zero compiles, bit-identical results to the exporting
    process.  Delegates to `repro.serve.aot.load_plan` (imported lazily:
    the serve subsystem is optional at import time).
    """
    from repro.serve.aot import load_plan as _load
    return _load(path, **kwargs)
