"""Gradient-trained Gaussian mixture — the paper's motivating workload,
EM-free.

Where examples/gmm_loglik.py runs classic EM (closed-form M-step), this
example *trains* the mixture by SGD on the negative log-likelihood

    NLL = -mean_x log sum_k softmax(w)_k N(x | mu_k, Sigma_k)

with ``Sigma_k = L_k L_k^T`` parameterized by its Cholesky factor (lower
triangle free, diagonal softplus-positive), so every step needs
``d NLL / d Sigma`` — which flows through a batched `repro.plan`'s custom
VJP (repro/estimators/grad.py).  The plan is compiled once before the
training loop; every SGD step executes it with a fresh PRNG key (runtime
input — no recompile).  With an estimator method the
whole logdet gradient stays matrix-free: the backward pass is one batched
CG solve on the forward's probe slab, vmapped over the K covariances; with
``--method mc`` it is the exact condensation forward and the analytic
``A^{-T}`` backward.  The Mahalanobis term uses the triangular factor
directly (two O(d^2) solves — differentiable, no dense inverse).

The Cholesky parameterization also gives a free exact reference
``logdet(Sigma_k) = 2 sum_i log L_k[i, i]``, logged as the estimator
fidelity monitor (`ld_gap`).

    PYTHONPATH=src python examples/gmm_fit.py --dim 32 --components 3
    PYTHONPATH=src python examples/gmm_fit.py --method slq --steps 200
    PYTHONPATH=src python examples/gmm_fit.py --method mc   # exact VJP
"""
import argparse

import numpy as np

import jax
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

try:
    import optax
except ImportError:                      # keep the example/test runnable
    optax = None

import repro


# ---------------------------------------------------------------- fallback

class _SGD:
    """Minimal optax.sgd stand-in for environments without optax."""

    def __init__(self, lr):
        self.lr = lr

    def init(self, params):
        return None

    def update(self, grads, state, params=None):
        return jax.tree_util.tree_map(lambda g: -self.lr * g, grads), state


def _apply_updates(params, updates):
    if optax is not None:
        return optax.apply_updates(params, updates)
    return jax.tree_util.tree_map(lambda p, u: p + u, params, updates)


def _make_optimizer(lr):
    if optax is not None:
        return optax.sgd(lr, momentum=0.9)
    return _SGD(lr)


# ------------------------------------------------------------------- model

def make_data(rng, dim, components, samples):
    """Well-separated synthetic mixture with anisotropic covariances."""
    mu = rng.standard_normal((components, dim)) * 3.0
    chunks = []
    for j in range(components):
        m = np.eye(dim) + 0.2 * rng.standard_normal((dim, dim))
        chunks.append(mu[j] + rng.standard_normal(
            (samples // components, dim)) @ m)
    return np.concatenate(chunks), mu


def init_params(rng, dim, components, x):
    """Means at random data points, near-unit Cholesky factors."""
    idx = rng.choice(x.shape[0], size=components, replace=False)
    return {
        "mu": jnp.asarray(x[idx] + 0.1 * rng.standard_normal(
            (components, dim))),
        "logit_w": jnp.zeros((components,)),
        # softplus(0.55) ~ 1.0: identity-ish initial covariances
        "chol_diag_raw": jnp.full((components, dim), 0.55),
        "chol_low": jnp.zeros((components, dim, dim)),
    }


def cholesky_factors(params):
    """(K, d, d) lower-triangular factors with positive diagonal."""
    low = jnp.tril(params["chol_low"], -1)
    diag = jax.nn.softplus(params["chol_diag_raw"]) + 1e-3
    return low + jnp.einsum("kd,de->kde", diag, jnp.eye(diag.shape[-1]))


def make_logdet_plan(components, dim, *, method, num_probes, degree,
                     num_steps):
    """Compile the (K, d, d) -> (K,) logdet plan once, before training."""
    shape = (components, dim, dim)
    if method == "mc":
        # exact engine route, vmapped per component matrix
        return repro.plan(shape, method="exact", schedule="serial")
    if method == "chebyshev":
        return repro.plan(shape, method="chebyshev",
                          num_probes=num_probes, degree=degree)
    return repro.plan(shape, method="slq",
                      num_probes=num_probes, num_steps=num_steps)


def nll(params, x, key, *, ld_plan):
    """Mixture NLL; the logdet term rides the batched plan's custom VJP."""
    chol = cholesky_factors(params)                     # (K, d, d)
    sigma = jnp.einsum("kij,klj->kil", chol, chol)      # L L^T, SPD stack
    d = x.shape[1]

    if ld_plan.method == "mc":
        ld = ld_plan.logdet(sigma)
    else:
        ld = ld_plan.logdet(sigma, key=key)

    # Mahalanobis through the factor: ||L^{-1}(x - mu)||^2, O(d^2)/sample
    xc = x[None, :, :] - params["mu"][:, None, :]       # (K, n, d)
    y = jax.vmap(lambda l, v: jax.scipy.linalg.solve_triangular(
        l, v.T, lower=True))(chol, xc)                  # (K, d, n)
    quad = (y ** 2).sum(1)                              # (K, n)

    logp = (jax.nn.log_softmax(params["logit_w"])[:, None]
            - 0.5 * (d * jnp.log(2 * jnp.pi) + ld[:, None] + quad))
    return -jax.nn.logsumexp(logp, axis=0).mean()


# ---------------------------------------------------------------- training

def train(*, dim=32, components=3, samples=600, steps=100, method="chebyshev",
          num_probes=16, degree=32, num_steps=15, lr=0.05, seed=0,
          log_every=10):
    """SGD on the mixture NLL; returns the training history.

    ``history["nll"]`` is the per-step loss (with estimator methods the
    logdet term is stochastic — fresh probes each step via key folding);
    ``history["ld_gap"]`` tracks |estimated - exact| logdet averaged over
    components, the estimator-fidelity monitor.
    """
    rng = np.random.default_rng(seed)
    data, _ = make_data(rng, dim, components, samples)
    x = jnp.asarray(data)
    params = init_params(rng, dim, components, x)
    ld_plan = make_logdet_plan(components, dim, method=method,
                               num_probes=num_probes, degree=degree,
                               num_steps=num_steps)

    loss_fn = lambda p, k: nll(p, x, k, ld_plan=ld_plan)
    value_and_grad = jax.jit(jax.value_and_grad(loss_fn))
    opt = _make_optimizer(lr)
    opt_state = opt.init(params)
    base_key = jax.random.PRNGKey(seed)

    @jax.jit
    def ld_gap(p, k):
        chol = cholesky_factors(p)
        exact = 2.0 * jnp.log(jnp.diagonal(chol, axis1=-2, axis2=-1)).sum(-1)
        if method == "mc":
            return jnp.zeros(())
        sigma = jnp.einsum("kij,klj->kil", chol, chol)
        est = ld_plan.logdet(sigma, key=k)
        return jnp.abs(est - exact).mean()

    history = {"nll": [], "ld_gap": []}
    for step in range(steps):
        key = jax.random.fold_in(base_key, step)
        val, grads = value_and_grad(params, key)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = _apply_updates(params, updates)
        history["nll"].append(float(val))
        history["ld_gap"].append(float(ld_gap(params, key)))
        if log_every and step % log_every == 0:
            print(f"step {step:4d}  nll/sample = {float(val):.4f}  "
                  f"logdet |est-exact| = {history['ld_gap'][-1]:.3e}")
    history["nll"] = np.asarray(history["nll"])
    history["ld_gap"] = np.asarray(history["ld_gap"])
    history["params"] = params
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--components", type=int, default=3)
    ap.add_argument("--samples", type=int, default=600)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--method", choices=("chebyshev", "slq", "mc"),
                    default="chebyshev",
                    help="logdet path: stochastic estimators (matrix-free "
                         "CG backward) or exact condensation (A^-T backward)")
    ap.add_argument("--num-probes", type=int, default=16)
    ap.add_argument("--degree", type=int, default=32)
    ap.add_argument("--num-steps", type=int, default=15)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if optax is None:
        print("[gmm_fit] optax not installed — using the built-in SGD")
    hist = train(dim=args.dim, components=args.components,
                 samples=args.samples, steps=args.steps, method=args.method,
                 num_probes=args.num_probes, degree=args.degree,
                 num_steps=args.num_steps, lr=args.lr, seed=args.seed)
    print(f"\nNLL: {hist['nll'][0]:.4f} -> {hist['nll'][-1]:.4f} "
          f"({args.steps} steps, method={args.method})")
    assert hist["nll"][-1] < hist["nll"][0], "training failed to reduce NLL"


if __name__ == "__main__":
    main()
