"""Serving example: batched prefill + token-by-token decode with sampling.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-1b --gen 24

Every registered arch works (smoke-sized weights, randomly initialized —
the point is the serving machinery: prefill caches, decode steps, batched
requests, enc-dec/vision extras).
"""
import argparse

from repro.launch import serve as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()
    S.main(["--arch", args.arch, "--batch", str(args.batch),
            "--prompt-len", str(args.prompt_len), "--gen", str(args.gen),
            "--temperature", str(args.temperature)])


if __name__ == "__main__":
    main()
