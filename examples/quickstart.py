"""Quickstart: log-determinant of a large matrix with every method,
through the plan/execute API (`repro.plan`): each method compiles into a
reusable `LogdetPlan` whose execution returns a unified `LogdetResult`
(sign, logabsdet, Monte-Carlo sem, diagnostics).  The last row lets the
``method="auto"`` cost model pick for itself.

    PYTHONPATH=src python examples/quickstart.py [--n 512]

For the parallel methods on >1 device, run under fake devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py --n 512
"""
import argparse
import time

import jax
jax.config.update("jax_enable_x64", True)

import numpy as np

import repro
from repro.core import METHODS
from repro.core.configs import LEGACY_EXACT_ROUTES
from repro.data.synthetic import random_matrix
from repro.launch.mesh import make_rows_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=512)
    args = ap.parse_args()

    a = random_matrix(args.n, kind="normal", seed=0)
    s_ref, ld_ref = np.linalg.slogdet(a)
    # the stochastic estimators assume SPD input: showcase them on a
    # well-conditioned covariance-like matrix with its own reference
    a_spd = random_matrix(args.n, kind="spd", seed=0) + 2.0 * np.eye(args.n)
    _, ld_spd_ref = np.linalg.slogdet(a_spd)
    print(f"numpy.linalg.slogdet reference: sign={s_ref:+.0f} "
          f"logdet={ld_ref:.12f}\n")

    mesh = make_rows_mesh(jax.device_count())
    print(f"devices: {jax.device_count()}  (methods p* use all of them)\n")

    estimators = {"chebyshev", "slq"}
    # the legacy route strings are deprecated aliases of method="exact"
    # engine tuples — the engine row plus the baselines cover everything
    methods = tuple(m for m in METHODS if m not in LEGACY_EXACT_ROUTES)
    for m in methods + ("auto",):
        kw = dict(mesh=mesh) if m.startswith("p") or m == "exact" else {}
        x, want_s, want_ld = a, s_ref, ld_ref
        if m in estimators or m == "auto":
            kw = dict(num_probes=32, seed=0) if m != "auto" else {}
            x, want_s, want_ld = a_spd, 1.0, ld_spd_ref
        plan = repro.plan(x, method=m, **kw)     # compile once ...
        res = plan()                             # ... execute
        s, ld = res                              # LogdetResult unpacks
        dt = res.diagnostics.wall_time_s
        err = abs(float(ld) - want_ld)
        stochastic = res.method_used in estimators
        tol = abs(want_ld) * 2e-2 if stochastic else 1e-8
        flag = "OK " if (float(s) == want_s and err < tol) else "BAD"
        note = f"  (SPD, sem={float(res.sem):.2e})" if stochastic else ""
        label = m if m == res.method_used else f"{m}->{res.method_used}"
        print(f"  {label:16s} sign={float(s):+.0f} "
              f"logdet={float(ld):.12f} "
              f"|err|={err:.2e}  {dt*1e3:8.1f} ms  [{flag}]{note}")


if __name__ == "__main__":
    main()
