"""Quickstart: log-determinant of a large matrix with every method.

    PYTHONPATH=src python examples/quickstart.py [--n 512]

For the parallel methods on >1 device, run under fake devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py --n 512
"""
import argparse
import time

import jax
jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import slogdet, METHODS
from repro.data.synthetic import random_matrix
from repro.launch.mesh import make_rows_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=512)
    args = ap.parse_args()

    a = random_matrix(args.n, kind="normal", seed=0)
    s_ref, ld_ref = np.linalg.slogdet(a)
    # the stochastic estimators assume SPD input: showcase them on a
    # well-conditioned covariance-like matrix with its own reference
    a_spd = random_matrix(args.n, kind="spd", seed=0) + 2.0 * np.eye(args.n)
    _, ld_spd_ref = np.linalg.slogdet(a_spd)
    print(f"numpy.linalg.slogdet reference: sign={s_ref:+.0f} "
          f"logdet={ld_ref:.12f}\n")

    mesh = make_rows_mesh(jax.device_count())
    print(f"devices: {jax.device_count()}  (methods p* use all of them)\n")

    estimators = {"chebyshev", "slq"}
    for m in METHODS:
        kw = dict(mesh=mesh) if m.startswith("p") else {}
        x, want_s, want_ld = a, s_ref, ld_ref
        if m in estimators:
            kw = dict(num_probes=32, seed=0)
            x, want_s, want_ld = a_spd, 1.0, ld_spd_ref
        t0 = time.perf_counter()
        s, ld = slogdet(x, method=m, **kw)
        jax.block_until_ready(ld)
        dt = time.perf_counter() - t0
        err = abs(float(ld) - want_ld)
        tol = abs(want_ld) * 2e-2 if m in estimators else 1e-8
        flag = "OK " if (float(s) == want_s and err < tol) else "BAD"
        note = "  (SPD, stochastic)" if m in estimators else ""
        print(f"  {m:12s} sign={float(s):+.0f} logdet={float(ld):.12f} "
              f"|err|={err:.2e}  {dt*1e3:8.1f} ms  [{flag}]{note}")


if __name__ == "__main__":
    main()
