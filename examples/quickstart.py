"""Quickstart: log-determinant of a large matrix with every method.

    PYTHONPATH=src python examples/quickstart.py [--n 512]

For the parallel methods on >1 device, run under fake devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py --n 512
"""
import argparse
import time

import jax
jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import slogdet, METHODS
from repro.data.synthetic import random_matrix
from repro.launch.mesh import make_rows_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=512)
    args = ap.parse_args()

    a = random_matrix(args.n, kind="normal", seed=0)
    s_ref, ld_ref = np.linalg.slogdet(a)
    print(f"numpy.linalg.slogdet reference: sign={s_ref:+.0f} "
          f"logdet={ld_ref:.12f}\n")

    mesh = make_rows_mesh(jax.device_count())
    print(f"devices: {jax.device_count()}  (methods p* use all of them)\n")

    for m in METHODS:
        kw = dict(mesh=mesh) if m.startswith("p") else {}
        t0 = time.perf_counter()
        s, ld = slogdet(a, method=m, **kw)
        jax.block_until_ready(ld)
        dt = time.perf_counter() - t0
        err = abs(float(ld) - ld_ref)
        flag = "OK " if (float(s) == s_ref and err < 1e-8) else "BAD"
        print(f"  {m:12s} sign={float(s):+.0f} logdet={float(ld):.12f} "
              f"|err|={err:.2e}  {dt*1e3:8.1f} ms  [{flag}]")


if __name__ == "__main__":
    main()
