"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --steps 300

Uses the full framework path: config -> mesh -> sharded init -> fault-
tolerant loop (async checkpoints, straggler monitor) -> loss curve.  On this
CPU container the default is a 100M-param config at short sequence length;
`--arch` selects any of the 10 registered architectures (smoke-sized).
The optional --logdet-reg exercises the paper's technique as a training
feature (decorrelation aux loss via the condensation core).
"""
import argparse
import sys

import jax

from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--arch", default="lm100m")
    ap.add_argument("--logdet-reg", type=float, default=0.0)
    args = ap.parse_args()

    if args.arch == "lm100m":
        # ~100M dense transformer (GPT-2-small-ish), trained for real
        import repro.configs.qwen2_5_3b as q
        from repro.configs import registry

        def lm100m():
            return q.full().replace(
                name="lm100m", n_layers=12, d_model=768, n_heads=12,
                n_kv_heads=12, head_dim=64, d_ff=2048, vocab=32768,
                qkv_bias=False)
        registry._MODULES = dict(registry._MODULES)
        mod = type(sys)("lm100m_cfg")
        mod.full = lm100m
        mod.smoke = lm100m
        mod.SKIP_SHAPES = set()
        sys.modules["repro.configs._lm100m"] = mod
        registry._MODULES["lm100m"] = "repro.configs._lm100m"
        registry.ARCHS = tuple(registry._MODULES)

    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--log-every", "10"]
    if args.arch == "lm100m":
        argv.append("--full")          # lm100m's full() IS the 100M config
    if args.logdet_reg:
        argv += ["--logdet-reg", str(args.logdet_reg)]
    T.main(argv)


if __name__ == "__main__":
    main()
