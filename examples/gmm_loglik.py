"""The paper's motivating application (abstract): generative learning —
log-likelihood of a Gaussian mixture whose covariances are LARGE matrices.

    log N(x | mu, Sigma) = -1/2 [ d log(2 pi) + logdet(Sigma)
                                  + (x-mu)^T Sigma^-1 (x-mu) ]

Two costs per EM iteration, and two regimes for each:

  logdet(Sigma)  --logdet exact        parallel condensation, O(d^3)
                 --logdet chebyshev|slq stochastic estimators, O(matvecs)
                 --logdet auto         repro.plan's cost model decides
  Mahalanobis    --solver direct        jnp.linalg.solve, O(d^3)
                 --solver cg            matrix-free conjugate gradient on
                                        the SAME operator, O(iters) matvecs

All log-determinants go through the plan API: each path builds its
`repro.plan(...)` ONCE (outside the EM loop) and executes it per
iteration — method resolution, padding and jit tracing happen a single
time, and every path returns the same `LogdetResult` (estimator paths
report their Monte-Carlo standard error alongside the value).

With ``--solver cg`` the covariances are never materialized: each
component's Sigma = Xc^T diag(w) Xc / sum(w) + ridge*I is held as an
`EmpiricalCovOperator` (~15 lines, duck-typing the `LinearOperator`
protocol) whose matvec is two (n, d) GEMMs — O(n d) per probe column —
and whose diagonal is free, feeding both the logdet estimators and the
Jacobi-preconditioned CG.  The whole E-step is sub-cubic in d.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/gmm_loglik.py --dim 256 --components 3
    PYTHONPATH=src python examples/gmm_loglik.py --dim 512 --logdet slq
    PYTHONPATH=src python examples/gmm_loglik.py --dim 512 --solver cg
"""
import argparse

import jax
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

import repro
from repro.estimators import LinearOperator, cg_solve
from repro.launch.mesh import make_rows_mesh


class EmpiricalCovOperator(LinearOperator):
    """Implicit Sigma = Xc^T diag(w) Xc / sum(w) + ridge*I, never built.

    ``xc (n, d)`` centered data, ``w (n,)`` responsibilities.  The matvec
    is two tall-skinny GEMMs; the diagonal (for CG preconditioning and
    variance reduction) is a single weighted column-square sum.
    """

    def __init__(self, xc, w, ridge):
        self.xc = xc
        self.w = w
        self.wsum = w.sum() + 1e-9
        self.ridge = ridge
        self.shape = (xc.shape[1], xc.shape[1])
        self.dtype = xc.dtype

    def mm(self, v):  # (d, k) -> (d, k)
        return (self.xc.T @ (self.w[:, None] * (self.xc @ v))) / self.wsum \
            + self.ridge * v

    def diag(self):
        return (self.w[:, None] * self.xc**2).sum(0) / self.wsum + self.ridge


def make_batched_logdet_plan(k: int, d: int, *, how: str, mesh):
    """Compile the (K, d, d) stack logdet path ONCE, before the EM loop.

    Returns ``(plan, per_matrix)`` — ``per_matrix`` flags the distributed
    exact path, which condenses one covariance at a time over the mesh.
    """
    if how == "exact":
        if mesh.size > 1:
            return repro.plan((d, d), method="exact", schedule="mesh",
                              mesh=mesh), True
        return repro.plan((k, d, d), method="exact",
                          schedule="serial"), False
    kw = {}
    if how != "auto":
        kw["num_probes"] = 32
        if how == "chebyshev":
            kw["degree"] = 64
    p = repro.plan((k, d, d), method=how, **kw)
    if how == "auto":
        print(f"[plan] auto-selected logdet method: {p.method} "
              f"(est. {p.diagnostics.flops_est:.2e} FLOPs)")
    return p, False


def batched_logdets(covs, plan_, per_matrix: bool, seed: int = 0):
    """(K,) logdets of a (K, d, d) covariance stack through a plan."""
    if per_matrix:
        return jnp.stack([plan_.logdet(c) for c in covs])
    if plan_.method in ("chebyshev", "slq"):
        res = plan_(covs, key=jax.random.PRNGKey(seed))
        return res.logabsdet
    return plan_(covs).logabsdet


def operator_logdets(ops, *, how: str, seed: int = 0):
    """(K,) logdets of implicit covariance operators, one plan per op.

    ``how="auto"`` lets the cost model route each operator: the duck-typed
    `EmpiricalCovOperator` is not materializable, so the selector stays in
    the estimator family regardless of d.
    """
    kw = {}
    if how != "auto":
        kw["num_probes"] = 32
        if how == "chebyshev":
            kw["degree"] = 64
    outs = []
    for op in ops:
        p = repro.plan(op, method=how, **kw)
        outs.append(p(key=jax.random.PRNGKey(seed)).logabsdet)
    return jnp.stack(outs)


def gaussian_loglik(x, mu, solve_fn, ld):
    """Mean log-density of rows of x under N(mu, Sigma); ld = logdet(Sigma).

    ``solve_fn`` maps a (d, n) right-hand-side slab to Sigma^{-1} @ rhs —
    dense factorization or matrix-free CG, the density does not care.
    """
    d = x.shape[1]
    xc = x - mu
    sol = solve_fn(xc.T)                        # (d, n)
    quad = jnp.einsum("nd,dn->n", xc, sol)
    return -0.5 * (d * jnp.log(2 * jnp.pi) + ld + quad)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--components", type=int, default=3)
    ap.add_argument("--samples", type=int, default=600)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--logdet", choices=("exact", "chebyshev", "slq", "auto"),
                    default="exact",
                    help="logdet path for the covariance stack ('auto' "
                         "lets repro.plan's cost model choose)")
    ap.add_argument("--solver", choices=("direct", "cg"), default="direct",
                    help="Mahalanobis solve: dense factorization or "
                         "matrix-free CG on implicit covariance operators")
    ap.add_argument("--cg-tol", type=float, default=1e-8)
    args = ap.parse_args()

    logdet_how = args.logdet
    if args.solver == "cg" and logdet_how == "exact":
        # exact condensation would materialize Sigma; stay matrix-free
        logdet_how = "slq"
        print("[--solver cg] switching --logdet exact -> slq "
              "(keeping the E-step matrix-free)")

    rng = np.random.default_rng(0)
    d, k, n = args.dim, args.components, args.samples
    mesh = make_rows_mesh(jax.device_count())

    # ground-truth mixture
    true_mu = rng.standard_normal((k, d)) * 3
    data = np.concatenate([
        true_mu[j] + rng.standard_normal((n // k, d)) @
        (np.eye(d) + 0.1 * rng.standard_normal((d, d)))
        for j in range(k)
    ])
    x = jnp.asarray(data)

    # init: random means; unit covariance == zero-weight operator + ridge 1
    mu = jnp.asarray(true_mu + rng.standard_normal((k, d)))
    pi = jnp.ones((k,)) / k
    resp_w = jnp.zeros((x.shape[0], k))
    ridge = 1.0

    if args.solver != "cg":
        # the plan (method resolution + compile) happens once, here; the
        # EM loop below only executes it
        ld_plan, per_matrix = make_batched_logdet_plan(
            k, d, how=logdet_how, mesh=mesh)

    for it in range(args.iters):
        # E-step: per-component logdet + Mahalanobis solve, then the
        # responsibilities via the per-component log-densities
        if args.solver == "cg":
            ops = [EmpiricalCovOperator(x - mu[j], resp_w[:, j], ridge)
                   for j in range(k)]
            lds = operator_logdets(ops, how=logdet_how, seed=it)
            solvers = [
                (lambda rhs, op=op: cg_solve(op, rhs, tol=args.cg_tol).x)
                for op in ops]
        else:
            cov = jnp.stack([
                ((resp_w[:, j, None] * (x - mu[j])).T @ (x - mu[j]))
                / (resp_w[:, j].sum() + 1e-9) + ridge * jnp.eye(d)
                for j in range(k)])
            lds = batched_logdets(cov, ld_plan, per_matrix, seed=it)
            solvers = [(lambda rhs, c=c: jnp.linalg.solve(c, rhs))
                       for c in cov]
        logp = jnp.stack([gaussian_loglik(x, mu[j], solvers[j], lds[j])
                          for j in range(k)], axis=1)
        logp = logp + jnp.log(pi)[None]
        ll = jax.nn.logsumexp(logp, axis=1)
        resp = jnp.exp(logp - ll[:, None])
        print(f"iter {it}: mixture log-likelihood/sample = {ll.mean():.4f}"
              f"  [logdet: {logdet_how}, solver: {args.solver}]")

        # M-step: means and weights; covariances are re-expressed from
        # (mu, resp) next E-step — as operators (cg) or dense (direct)
        nk = resp.sum(0) + 1e-9
        pi = nk / nk.sum()
        mu = (resp.T @ x) / nk[:, None]
        resp_w = resp
        ridge = 1e-3

    print("\nfinal mixture weights:", np.round(np.asarray(pi), 3))
    print("mean abs error of recovered means:",
          float(jnp.abs(jnp.sort(mu, 0) - jnp.sort(jnp.asarray(true_mu), 0)).mean()))


if __name__ == "__main__":
    main()
