"""The paper's motivating application (abstract): generative learning —
log-likelihood of a Gaussian mixture whose covariances are LARGE matrices.

    log N(x | mu, Sigma) = -1/2 [ d log(2 pi) + logdet(Sigma)
                                  + (x-mu)^T Sigma^-1 (x-mu) ]

The logdet(Sigma) terms for ALL mixture components are computed in one
``logdet_batched`` call per EM iteration over the (K, d, d) covariance
stack: exact parallel condensation for small d, or the stochastic
estimators (``--logdet chebyshev|slq``) which make the logdet term
sub-cubic.  (The Mahalanobis ``solve`` in the density is still O(d^3)
here — replacing it with CG on the same matvec backends is the
remaining step to a fully sub-cubic E-step; see ROADMAP.)
Responsibilities and the EM-style refit keep running until the mixture
log-likelihood converges.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/gmm_loglik.py --dim 256 --components 3
    PYTHONPATH=src python examples/gmm_loglik.py --dim 512 --logdet slq
"""
import argparse

import jax
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import logdet_batched, slogdet
from repro.launch.mesh import make_rows_mesh


def batched_logdets(covs, *, how: str, mesh, seed: int = 0):
    """(K,) logdets of a (K, d, d) covariance stack, by configured path."""
    if how == "exact":
        if mesh.size > 1:
            # distributed exact condensation, one covariance at a time
            return jnp.stack([slogdet(c, method="pmc", mesh=mesh)[1]
                              for c in covs])
        return logdet_batched(covs, method="mc")
    kw = dict(num_probes=32, seed=seed)
    if how == "chebyshev":
        kw["degree"] = 64
    return logdet_batched(covs, method=how, **kw)


def gaussian_loglik(x, mu, cov, ld):
    """Mean log-density of rows of x under N(mu, cov); ld = logdet(cov)."""
    d = x.shape[1]
    xc = x - mu
    sol = jnp.linalg.solve(cov, xc.T)           # (d, n)
    quad = jnp.einsum("nd,dn->n", xc, sol)
    return -0.5 * (d * jnp.log(2 * jnp.pi) + ld + quad)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--components", type=int, default=3)
    ap.add_argument("--samples", type=int, default=600)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--logdet", choices=("exact", "chebyshev", "slq"),
                    default="exact",
                    help="logdet path for the covariance stack")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    d, k, n = args.dim, args.components, args.samples
    mesh = make_rows_mesh(jax.device_count())

    # ground-truth mixture
    true_mu = rng.standard_normal((k, d)) * 3
    data = np.concatenate([
        true_mu[j] + rng.standard_normal((n // k, d)) @
        (np.eye(d) + 0.1 * rng.standard_normal((d, d)))
        for j in range(k)
    ])
    x = jnp.asarray(data)

    # init: random means, identity covs
    mu = jnp.asarray(true_mu + rng.standard_normal((k, d)))
    cov = jnp.stack([jnp.eye(d) for _ in range(k)])
    pi = jnp.ones((k,)) / k

    for it in range(args.iters):
        # E-step: one batched logdet over the covariance stack, then the
        # responsibilities via the per-component log-densities
        lds = batched_logdets(cov, how=args.logdet, mesh=mesh, seed=it)
        logp = jnp.stack([gaussian_loglik(x, mu[j], cov[j], lds[j])
                          for j in range(k)], axis=1)
        logp = logp + jnp.log(pi)[None]
        ll = jax.nn.logsumexp(logp, axis=1)
        resp = jnp.exp(logp - ll[:, None])
        print(f"iter {it}: mixture log-likelihood/sample = {ll.mean():.4f}"
              f"  [logdet: {args.logdet}]")

        # M-step
        nk = resp.sum(0) + 1e-9
        pi = nk / nk.sum()
        mu = (resp.T @ x) / nk[:, None]
        cov = jnp.stack([
            ((resp[:, j, None] * (x - mu[j])).T @ (x - mu[j])) / nk[j]
            + 1e-3 * jnp.eye(d)
            for j in range(k)])

    print("\nfinal mixture weights:", np.round(np.asarray(pi), 3))
    print("mean abs error of recovered means:",
          float(jnp.abs(jnp.sort(mu, 0) - jnp.sort(jnp.asarray(true_mu), 0)).mean()))


if __name__ == "__main__":
    main()
